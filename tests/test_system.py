"""End-to-end system behaviour: train -> checkpoint -> resume -> serve,
plus the paper's application demo running on the emulated macro."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get_config
from repro.data import DataConfig, batch_at
from repro.launch.train import train
from repro.models import lm


def test_train_checkpoint_serve_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    params, opt_state, losses = train(
        "musicgen-medium", smoke=True, steps=8, batch=2, seq=32,
        ckpt_dir=d, ckpt_every=4, log_every=100)
    assert np.isfinite(losses).all()
    assert ckpt.latest_step(d) == 8

    # restore and serve with the TRAINED weights
    cfg = get_config("musicgen-medium", smoke=True)
    state = ckpt.restore(d, {"params": params, "opt": opt_state})
    p2 = state["params"]
    toks = jnp.asarray(batch_at(DataConfig(cfg.vocab_size, 16, 2), 0)["tokens"])
    cache = lm.init_cache(cfg, 2, 24)
    logits, cache = lm.prefill(p2, cfg, toks, cache)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    outs = []
    for _ in range(4):
        logits, cache = lm.decode_step(p2, cfg, tok, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        outs.append(np.asarray(tok))
    gen = np.concatenate(outs, 1)
    assert gen.shape == (2, 4)
    assert (gen >= 0).all() and (gen < cfg.vocab_size).all()


def test_cim_execution_mode_end_to_end():
    """The paper's technique as execution mode: quantized serving output
    stays usable (same shapes, finite, tracks fp logits)."""
    cfg = get_config("minicpm-2b", smoke=True)
    cfg_cim = dataclasses.replace(cfg, cim_mode=True)
    key = jax.random.PRNGKey(0)
    params, _ = lm.init(key, cfg)
    toks = jax.random.randint(key, (2, 24), 0, cfg.vocab_size)
    lf, _ = lm.forward(params, cfg, toks, remat=False)
    lc, _ = lm.forward(params, cfg_cim, toks, remat=False)
    assert lc.shape == lf.shape
    assert bool(jnp.isfinite(lc).all())
    cos = jnp.sum(lf * lc) / (jnp.linalg.norm(lf) * jnp.linalg.norm(lc))
    assert float(cos) > 0.9, float(cos)


def test_doa_application_beats_paper_bound():
    """Fig. S3: DOA estimation through the macro, < 4% RMSE vs software."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.figS3_doa import _estimate, _music_spectrum, _steering
    rng = np.random.default_rng(3)
    true = np.array([-30.0, 22.0])
    A = _steering(8, true)
    S = (rng.standard_normal((2, 64)) + 1j * rng.standard_normal((2, 64)))
    N = (rng.standard_normal((8, 64)) + 1j * rng.standard_normal((8, 64))) * 0.05
    X = jnp.asarray(A @ S + N, jnp.complex64)
    grid = np.arange(-60.0, 60.5, 1.0)
    p = _music_spectrum(X, 2, grid, cim=True, key=jax.random.PRNGKey(1))
    est = _estimate(p, grid, 2)
    rmse = np.sqrt(np.mean((np.array(est) - true) ** 2))
    assert 100 * rmse / 120.0 < 4.0  # paper's bound, generously met
